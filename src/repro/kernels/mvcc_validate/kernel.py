"""Pallas TPU kernel for MVCC block validation.

The paper's "must be sequential" step (§III-D), restructured for TPU
(DESIGN.md §2): the pairwise conflict matrix — does tx j's write set touch
tx i's read+write set — is dense vectorized VPU work computed *in parallel*
inside VMEM; the irreducibly sequential part shrinks to a B-step boolean
scan that propagates one validity bit per transaction:

    valid[i] = ok0[i] & vers_ok[i] & !any_{j<i}(valid[j] & conflict[j, i])

Grid: one step per block (multiple blocks pipeline through the kernel, the
paper's multi-block validation pipeline). Per-block VMEM: the (B, B)
conflict matrix as float-free u32/bool work plus the key tensors —
B=512, RK=WK=4 is ~1.3 MiB, comfortably resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

U32 = jnp.uint32


def _mvcc_kernel(rk_ref, rv_ref, wk_ref, cur_ref, ok0_ref, valid_ref):
    """One block: refs are (1, B, ...) blocks; leading dim squeezed here."""
    read_keys = rk_ref[0]  # (B, RK, 2)
    read_vers = rv_ref[0]  # (B, RK)
    write_keys = wk_ref[0]  # (B, WK, 2)
    cur = cur_ref[0]  # (B, RK)
    ok0 = ok0_ref[0] != 0  # (B,)
    bsz = read_keys.shape[0]

    # --- Parallel part 1: read-set freshness. ---
    active_read = read_keys[..., 0] != jnp.uint32(0)
    vers_ok = jnp.where(active_read, cur == read_vers, True).all(axis=1)

    # --- Parallel part 2: pairwise conflict matrix (VPU broadcast work). ---
    touched = jnp.concatenate([read_keys, write_keys], axis=1)  # (B, T, 2)
    eq = (
        (write_keys[:, None, :, None, 0] == touched[None, :, None, :, 0])
        & (write_keys[:, None, :, None, 1] == touched[None, :, None, :, 1])
        & (write_keys[:, None, :, None, 0] != jnp.uint32(0))
    )  # (j, i, WK, T)
    conf = eq.any(axis=(2, 3))  # (B, B): j's writes touch i

    # --- Sequential part: one validity bit per step. ---
    ok_static = ok0 & vers_ok
    idx = jax.lax.broadcasted_iota(jnp.int32, (bsz,), 0)

    def body(i, valid):
        mask = idx < i
        blocked = (conf[:, i] & valid & mask).any()
        v_i = ok_static[i] & ~blocked
        return valid.at[i].set(v_i)

    valid = jax.lax.fori_loop(0, bsz, body, jnp.zeros((bsz,), bool))
    valid_ref[0] = valid.astype(U32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def validate_blocks(read_keys, read_vers, write_keys, current_versions, ok0,
                    *, interpret: bool = True):
    """Validate NB blocks of B txs each. Inputs (NB, B, ...); out (NB, B) bool."""
    nb, b, rk, _ = read_keys.shape
    wk = write_keys.shape[2]
    spec = lambda *s: pl.BlockSpec((1, *s), lambda i: (i,) + (0,) * len(s))
    valid = pl.pallas_call(
        _mvcc_kernel,
        grid=(nb,),
        in_specs=[
            spec(b, rk, 2),
            spec(b, rk),
            spec(b, wk, 2),
            spec(b, rk),
            spec(b),
        ],
        out_specs=spec(b),
        out_shape=jax.ShapeDtypeStruct((nb, b), U32),
        interpret=interpret,
    )(read_keys, read_vers, write_keys, current_versions, ok0.astype(U32))
    return valid.astype(bool)
