"""Pure-jnp oracle for the MVCC validation kernel.

Canonical semantics live in repro.core.mvcc; this wrapper exposes the
kernel's exact interface (raw arrays in, valid flags out).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import mvcc, types


def validate_ref(read_keys, read_vers, write_keys, current_versions, ok0):
    """(B,RK,2),(B,RK),(B,WK,2),(B,RK),(B,) -> valid (B,) bool.

    ``ok0`` folds upstream checks (checksum, endorsement) into validity.
    """
    b = read_keys.shape[0]
    txb = types.TxBatch(
        tx_id=jnp.zeros((b, 2), jnp.uint32),
        client=jnp.zeros((b,), jnp.uint32),
        channel=jnp.zeros((b,), jnp.uint32),
        read_keys=read_keys,
        read_vers=read_vers,
        write_keys=write_keys,
        write_vals=jnp.zeros(
            (b, write_keys.shape[1], 1), jnp.uint32
        ),
        endorse_tags=jnp.zeros((b, 1), jnp.uint32),
    )
    res = mvcc.validate(txb, current_versions, checksum_ok=ok0)
    return res.valid
