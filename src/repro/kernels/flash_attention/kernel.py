"""Pallas TPU flash attention (forward), GQA-aware.

§Perf motivation: the train/prefill roofline is dominated by attention
score traffic — XLA materializes (B,H,S,S) f32 tiles at fusion boundaries
even under the chunked-scan formulation (EXPERIMENTS.md Cell A iter 3).
The VMEM-resident online-softmax kernel is the TPU-native fix: one
(q_block x kv_block) tile lives in VMEM per grid step, HBM sees only
Q/K/V/O.

Layout: grid (batch, q_heads, q_blocks); each step streams KV chunks for
its (batch, kv_head = q_head // group) through a fori_loop carrying the
(acc, m, l) online-softmax state. Causal masking prunes the KV loop bound
per q block (exact N^2/2 work). MXU-aligned tiles: q_block/kv_block
multiples of 128 on real hardware (tests use smaller interpret-mode
tiles).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool,
                  kv_block: int, q_block: int, seq_kv: int):
    qb = q_ref.shape[0]
    d = q_ref.shape[1]
    iq = pl.program_id(2)
    scale = 1.0 / math.sqrt(d)
    q = q_ref[...].astype(jnp.float32) * scale  # (qb, d)

    nk = seq_kv // kv_block
    if causal:
        # KV blocks strictly after this q block's last row are fully masked.
        hi = jnp.minimum(((iq + 1) * q_block + kv_block - 1) // kv_block, nk)
    else:
        hi = nk

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[pl.dslice(j * kv_block, kv_block), :].astype(jnp.float32)
        v = v_ref[pl.dslice(j * kv_block, kv_block), :].astype(jnp.float32)
        s = q @ k.T  # (qb, kb)
        if causal:
            qpos = iq * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (qb, kv_block), 0
            )
            kpos = j * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (qb, kv_block), 1
            )
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l

    acc0 = jnp.zeros((qb, d), jnp.float32)
    m0 = jnp.full((qb,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qb,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l[:, None], 1e-37)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_block", "kv_block", "interpret"),
)
def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 256,
                    kv_block: int = 256, interpret: bool = True):
    """q (B,S,H,D), k/v (B,Skv,Hkv,D) -> (B,S,H,D). GQA by head grouping."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if h % hkv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {hkv}")
    group = h // hkv
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    if sq % q_block or skv % kv_block:
        raise ValueError("sequence not divisible by block size")
    grid = (b, h, sq // q_block)

    kernel = functools.partial(
        _flash_kernel, causal=causal, kv_block=kv_block, q_block=q_block,
        seq_kv=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, q_block, None, d),
                         lambda bi, hi, qi: (bi, qi, hi, 0)),
            pl.BlockSpec((None, skv, None, d),
                         lambda bi, hi, qi: (bi, 0, hi // group, 0)),
            pl.BlockSpec((None, skv, None, d),
                         lambda bi, hi, qi: (bi, 0, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_block, None, d),
                               lambda bi, hi, qi: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
