"""Dispatch for flash attention: Pallas on TPU, XLA paths elsewhere."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import kernel, ref
from repro.models import layers


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal: bool = True,
              use_pallas: bool | None = None,
              q_block: int = 256, kv_block: int = 256):
    """Self-attention core. Pallas flash kernel on TPU; the exact-causal
    chunked-scan XLA formulation (models/layers.attn_chunked) on other
    backends for long sequences; naive scores for short ones."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return kernel.flash_attention(
            q, k, v, causal=causal, q_block=q_block, kv_block=kv_block,
            interpret=not _on_tpu(),
        )
    if q.shape[1] > 2 * q_block:
        return layers.attn_chunked(q, k, v, causal=causal,
                                   q_chunk=q_block, kv_chunk=kv_block)
    return ref.flash_attention_ref(q, k, v, causal=causal)
