"""Pure-jnp oracle for the flash-attention kernel (models/layers naive)."""

from __future__ import annotations

from repro.models import layers


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """(B,S,H,D) x (B,Skv,Hkv,D) -> (B,S,H,D), scores materialized."""
    return layers.attn_naive(q, k, v, causal=causal)
