"""Pure-jnp oracle for the hash-table probe/commit kernels.

Semantics are shared with repro.core.world_state (the engine's pure-JAX
path); re-exported here so kernel tests compare against one canonical
definition without importing engine internals.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import world_state as ws


def lookup_ref(tkeys, tvers, tvals, queries):
    """(NB,S,2),(NB,S),(NB,S,VW),(Q,2) -> found (Q,), vers (Q,), vals (Q,VW)."""
    st = ws.HashState(keys=tkeys, versions=tvers, values=tvals)
    out = ws.lookup(st, queries)
    return out.found, out.versions, out.values


def commit_ref(tkeys, tvers, tvals, wkeys, wvals, active):
    """Sequential insert-or-update; returns (keys, vers, vals, overflow).

    ``wkeys`` (K,2), ``wvals`` (K,VW), ``active`` (K,) bool.
    """
    st = ws.HashState(keys=tkeys, versions=tvers, values=tvals)
    res = ws.commit_sequential(
        st, wkeys[:, None, :], wvals[:, None, :], active
    )
    return res.state.keys, res.state.versions, res.state.values, res.overflow


def commit_window_ref(tkeys, tvers, tvals, log_keys, log_vals, log_bumps,
                      log_new):
    """Fused window commit oracle (one LWW scatter pass over a planned
    window write log; see world_state.commit_window for the log contract).
    Returns (keys, vers, vals)."""
    st = ws.HashState(keys=tkeys, versions=tvers, values=tvals)
    out = ws.commit_window(st, log_keys, log_vals, log_bumps, log_new)
    return out.keys, out.versions, out.values
