"""Jit'd dispatch for the hash-table kernels.

``use_pallas`` selects the Pallas kernel (interpret=True on CPU — the TPU
path drops interpret); the default (None) picks Pallas only on TPU backends
so CPU tests, benchmarks and the dry-run use the XLA reference path while
kernel tests exercise the Pallas path explicitly.

Also enforces the VMEM-residency sizing rule from kernel.py: a table that
exceeds the budget is not rejected — it is dispatched through the sharded
path (launch/state_sharding's high-bit bucket partition), running the
kernel once per shard with each slice VMEM-resident. Queries/writes route
to their owner shard by the high bits of the global bucket index, the same
partition the mesh ``model`` axis uses in launch/fabric_step.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import world_state as ws
from repro.kernels.hash_table import kernel, ref

VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def table_bytes(tkeys, tvals) -> int:
    nb, s, vw = tvals.shape
    return nb * s * (3 + vw) * 4


def _n_shards(tkeys, tvals) -> int:
    nb = tkeys.shape[0]
    return ws.shards_for_budget(
        table_bytes(tkeys, tvals), VMEM_BUDGET_BYTES, nb
    )


def lookup(tkeys, tvers, tvals, queries, *, use_pallas: bool | None = None):
    """(found, versions, values) for a batch of paired-hash queries."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        m = _n_shards(tkeys, tvals)
        if m > 1:
            return _sharded_lookup(tkeys, tvers, tvals, queries, m)
        return kernel.lookup(
            tkeys, tvers, tvals, queries, interpret=not _on_tpu()
        )
    return ref.lookup_ref(tkeys, tvers, tvals, queries)


def commit(tkeys, tvers, tvals, wkeys, wvals, active,
           *, use_pallas: bool | None = None):
    """Sequential insert-or-update commit. Returns (keys, vers, vals, ovf)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        m = _n_shards(tkeys, tvals)
        if m > 1:
            return _sharded_commit(tkeys, tvers, tvals, wkeys, wvals,
                                   active, m)
        return kernel.commit(
            tkeys, tvers, tvals, wkeys, wvals, active,
            interpret=not _on_tpu(),
        )
    return ref.commit_ref(tkeys, tvers, tvals, wkeys, wvals, active)


def commit_window(tkeys, tvers, tvals, log_keys, log_vals, log_bumps,
                  log_new):
    """Fused window commit (one LWW scatter pass; world_state.commit_window
    log contract). Over-budget tables dispatch per bucket shard: the log is
    replayed once per shard with non-owned entries blanked/masked, exactly
    the owner-shard masking of launch/state_sharding.commit_window_routed.
    The scatter itself is pure XLA (no per-write Pallas loop to fuse), so
    there is no separate kernel path. Returns (keys, vers, vals)."""
    m = _n_shards(tkeys, tvals)
    if m > 1:
        return _sharded_commit_window(
            tkeys, tvers, tvals, log_keys, log_vals, log_bumps, log_new, m
        )
    return ref.commit_window_ref(
        tkeys, tvers, tvals, log_keys, log_vals, log_bumps, log_new
    )


# ---------------------------------------------------------------------------
# Sharded dispatch: one jitted lax.scan over the bucket shards, each slice
# within the VMEM budget (ROADMAP "pipeline slice loads with probes": XLA
# overlaps the next slice's load with the current probe, and the whole
# sharded sweep is ONE compiled program instead of n_shards separate
# dispatches). Results/writes are routed by owner shard.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_shards", "interpret"))
def _sharded_lookup_scan(tkeys, tvers, tvals, queries, n_shards: int,
                         interpret: bool):
    nb = tkeys.shape[0]
    sk, sv, sva = ws.split_table(tkeys, tvers, tvals, n_shards)
    owner = ws.shard_of(nb, n_shards, queries)  # (Q,)
    q = queries.shape[0]
    vw = tvals.shape[2]

    def body(carry, xs):
        found, vers, vals = carry
        m, k, v, va = xs
        f, ver, val = kernel.lookup(k, v, va, queries, interpret=interpret)
        mine = owner == m
        return (
            jnp.where(mine, f, found),
            jnp.where(mine, ver, vers),
            jnp.where(mine[:, None], val, vals),
        ), None

    init = (
        jnp.zeros((q,), bool),
        jnp.zeros((q,), jnp.uint32),
        jnp.zeros((q, vw), jnp.uint32),
    )
    (found, vers, vals), _ = jax.lax.scan(
        body, init, (jnp.arange(n_shards), sk, sv, sva)
    )
    return found, vers, vals


def _sharded_lookup(tkeys, tvers, tvals, queries, n_shards: int):
    return _sharded_lookup_scan(
        tkeys, tvers, tvals, queries, n_shards, not _on_tpu()
    )


@functools.partial(jax.jit, static_argnames=("n_shards", "interpret"))
def _sharded_commit_scan(tkeys, tvers, tvals, wkeys, wvals, active,
                         n_shards: int, interpret: bool):
    nb = tkeys.shape[0]
    sk, sv, sva = ws.split_table(tkeys, tvers, tvals, n_shards)
    owner = ws.shard_of(nb, n_shards, wkeys)  # (K,)

    def body(ovf, xs):
        m, k, v, va = xs
        k2, v2, va2, o = kernel.commit(
            k, v, va, wkeys, wvals, active & (owner == m),
            interpret=interpret,
        )
        return ovf | o, (k2, v2, va2)

    ovf, (ks, vs, vls) = jax.lax.scan(
        body, jnp.asarray(False), (jnp.arange(n_shards), sk, sv, sva)
    )
    okeys, overs, ovals = ws.merge_table(ks, vs, vls)
    return okeys, overs, ovals, ovf


def _sharded_commit(tkeys, tvers, tvals, wkeys, wvals, active, n_shards: int):
    return _sharded_commit_scan(
        tkeys, tvers, tvals, wkeys, wvals, active, n_shards, not _on_tpu()
    )


@functools.partial(jax.jit, static_argnames=("n_shards",))
def _sharded_commit_window(tkeys, tvers, tvals, log_keys, log_vals,
                           log_bumps, log_new, n_shards: int):
    nb = tkeys.shape[0]
    sk, sv, sva = ws.split_table(tkeys, tvers, tvals, n_shards)
    owner = ws.shard_of(nb, n_shards, log_keys)  # (L,)

    def body(_, xs):
        m, k, v, va = xs
        mine = owner == m
        st = ws.commit_window(
            ws.HashState(k, v, va),
            jnp.where(mine[:, None], log_keys, jnp.uint32(0)),
            log_vals, log_bumps & mine, log_new & mine,
        )
        return None, (st.keys, st.versions, st.values)

    _, (ks, vs, vls) = jax.lax.scan(
        body, None, (jnp.arange(n_shards), sk, sv, sva)
    )
    return ws.merge_table(ks, vs, vls)
