"""Jit'd dispatch for the hash-table kernels.

``use_pallas`` selects the Pallas kernel (interpret=True on CPU — the TPU
path drops interpret); the default (None) picks Pallas only on TPU backends
so CPU tests, benchmarks and the dry-run use the XLA reference path while
kernel tests exercise the Pallas path explicitly.

Also enforces the VMEM-residency sizing rule from kernel.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hash_table import kernel, ref

VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def table_bytes(tkeys, tvals) -> int:
    nb, s, vw = tvals.shape
    return nb * s * (3 + vw) * 4


def lookup(tkeys, tvers, tvals, queries, *, use_pallas: bool | None = None):
    """(found, versions, values) for a batch of paired-hash queries."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        if table_bytes(tkeys, tvals) > VMEM_BUDGET_BYTES:
            raise ValueError(
                "state shard exceeds the VMEM residency budget; shard the "
                "table over the mesh 'model' axis (see kernel.py)"
            )
        return kernel.lookup(
            tkeys, tvers, tvals, queries, interpret=not _on_tpu()
        )
    return ref.lookup_ref(tkeys, tvers, tvals, queries)


def commit(tkeys, tvers, tvals, wkeys, wvals, active,
           *, use_pallas: bool | None = None):
    """Sequential insert-or-update commit. Returns (keys, vers, vals, ovf)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        if table_bytes(tkeys, tvals) > VMEM_BUDGET_BYTES:
            raise ValueError(
                "state shard exceeds the VMEM residency budget; shard the "
                "table over the mesh 'model' axis (see kernel.py)"
            )
        return kernel.commit(
            tkeys, tvers, tvals, wkeys, wvals, active,
            interpret=not _on_tpu(),
        )
    return ref.commit_ref(tkeys, tvers, tvals, wkeys, wvals, active)
