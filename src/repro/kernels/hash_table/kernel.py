"""Pallas TPU kernels for the in-VMEM world-state hash table (Opt P-I).

Hardware adaptation (DESIGN.md §2): the paper moves world state up the
memory hierarchy (disk -> RAM). On TPU the same move is HBM -> VMEM: the
state shard is bucket-major and *stays VMEM-resident across the whole grid*
(BlockSpec index_map pins block 0), so every probe is a VMEM random access
instead of an HBM gather. Random access inside VMEM is cheap; the per-query
work is a short vector compare over the bucket's slots (VPU lanes).

Sizing rule (ops.py enforces): table bytes = NB*S*(3+VW)*4 must fit the
VMEM budget (default 8 MiB) per kernel invocation; larger states are
sharded by high bucket bits — over mesh 'model' ranks in the distributed
step (launch/state_sharding), or by ops.py's per-slice dispatch on a
single device (one pallas_call per shard, each slice VMEM-resident) —
never over sequential grid steps, because the table is mutable state and
grid-step sharding would re-stream HBM, which is exactly what P-I is
designed to avoid.

Kernels:
  * lookup:  grid over query tiles; table resident; probes are dynamic-slice
    loads of one bucket row per query.
  * commit:  single grid step; sequential fori_loop applies insert-or-update
    write-by-write (the paper's "must be updated sequentially"); the table
    is aliased input->output so the update is in-place in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# NOTE: constants are constructed *inside* kernel bodies — module-level jnp
# constants would be captured as tracer consts, which pallas_call rejects.

U32 = jnp.uint32


def _probe_row(row_k, row_v, row_val, k0, k1):
    """Vector probe of one bucket row. row_k (S,2) -> scalar hit/vers, (VW,)."""
    nonempty = row_k[:, 0] != jnp.uint32(0)
    match = (row_k[:, 0] == k0) & (row_k[:, 1] == k1) & nonempty
    found = match.any()
    # At most one slot matches: masked-max extracts without dynamic indexing.
    vers = jnp.max(jnp.where(match, row_v, jnp.uint32(0)))
    vals = jnp.max(jnp.where(match[:, None], row_val, jnp.uint32(0)), axis=0)
    return found, vers, vals


def _lookup_kernel(q_ref, tkeys_ref, tvers_ref, tvals_ref,
                   found_ref, vers_ref, vals_ref):
    """One grid step: probe TQ queries against the VMEM-resident table."""
    nb = tkeys_ref.shape[0]
    tq = q_ref.shape[0]

    def body(i, _):
        k0 = q_ref[i, 0]
        k1 = q_ref[i, 1]
        b = (k0 & jnp.uint32(nb - 1)).astype(jnp.int32)
        row_k = tkeys_ref[pl.dslice(b, 1)][0]  # (S, 2)
        row_v = tvers_ref[pl.dslice(b, 1)][0]  # (S,)
        row_val = tvals_ref[pl.dslice(b, 1)][0]  # (S, VW)
        hit, vers, vals = _probe_row(row_k, row_v, row_val, k0, k1)
        empty_q = k0 == jnp.uint32(0)
        found_ref[pl.dslice(i, 1)] = (hit & ~empty_q).astype(U32)[None]
        vers_ref[pl.dslice(i, 1)] = jnp.where(empty_q, jnp.uint32(0), vers)[None]
        vals_ref[pl.dslice(i, 1)] = jnp.where(
            empty_q, jnp.uint32(0), vals
        )[None]
        return 0

    jax.lax.fori_loop(0, tq, body, 0)


@functools.partial(jax.jit, static_argnames=("q_tile", "interpret"))
def lookup(tkeys, tvers, tvals, queries, *, q_tile: int = 128,
           interpret: bool = True):
    """Batched probe. queries (Q,2); Q padded to q_tile multiples.

    Returns (found (Q,) bool, versions (Q,), values (Q,VW)).
    """
    q = queries.shape[0]
    nb, s, vw = tvals.shape
    pad = (-q) % q_tile
    qp = jnp.pad(queries, ((0, pad), (0, 0)))
    grid = (qp.shape[0] // q_tile,)
    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    found, vers, vals = pl.pallas_call(
        _lookup_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_tile, 2), lambda i: (i, 0)),
            whole((nb, s, 2)),
            whole((nb, s)),
            whole((nb, s, vw)),
        ],
        out_specs=[
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((q_tile,), lambda i: (i,)),
            pl.BlockSpec((q_tile, vw), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp.shape[0],), U32),
            jax.ShapeDtypeStruct((qp.shape[0],), U32),
            jax.ShapeDtypeStruct((qp.shape[0], vw), U32),
        ],
        interpret=interpret,
    )(qp, tkeys, tvers, tvals)
    return found[:q].astype(bool), vers[:q], vals[:q]


def _commit_kernel(wk_ref, wv_ref, act_ref, _tk_ref, _tv_ref, _tval_ref,
                   okeys_ref, overs_ref, ovals_ref, ovf_ref):
    """Sequential insert-or-update; table aliased in-place (VMEM-resident).

    ``_tk/_tv/_tval`` are the aliased input refs — the kernel works on the
    output refs, which share their memory (input_output_aliases)."""
    nb = okeys_ref.shape[0]
    s = okeys_ref.shape[1]
    k = wk_ref.shape[0]
    ovf_ref[0] = jnp.uint32(0)

    def body(i, _):
        k0 = wk_ref[i, 0]
        k1 = wk_ref[i, 1]
        a = (act_ref[i] != 0) & (k0 != jnp.uint32(0))
        b = (k0 & jnp.uint32(nb - 1)).astype(jnp.int32)
        row_k = okeys_ref[pl.dslice(b, 1)][0]  # (S, 2)
        row_v = overs_ref[pl.dslice(b, 1)][0]  # (S,)
        nonempty = row_k[:, 0] != jnp.uint32(0)
        match = (row_k[:, 0] == k0) & (row_k[:, 1] == k1) & nonempty
        exists = match.any()
        empty = ~nonempty
        has_empty = empty.any()
        # Slot: the match if present, else the first empty slot.
        slot_idx = jnp.where(exists, jnp.argmax(match), jnp.argmax(empty))
        ok = a & (exists | has_empty)
        ovf_ref[0] = ovf_ref[0] | (a & ~exists & ~has_empty).astype(U32)
        old_ver = jnp.max(jnp.where(match, row_v, jnp.uint32(0)))
        new_ver = jnp.where(exists, old_ver + 1, jnp.uint32(1))

        old_key = okeys_ref[pl.dslice(b, 1), pl.dslice(slot_idx, 1)]
        okeys_ref[pl.dslice(b, 1), pl.dslice(slot_idx, 1)] = jnp.where(
            ok, jnp.stack([k0, k1])[None, None], old_key
        )
        old_vv = overs_ref[pl.dslice(b, 1), pl.dslice(slot_idx, 1)]
        overs_ref[pl.dslice(b, 1), pl.dslice(slot_idx, 1)] = jnp.where(
            ok, new_ver[None, None], old_vv
        )
        old_val = ovals_ref[pl.dslice(b, 1), pl.dslice(slot_idx, 1)]
        ovals_ref[pl.dslice(b, 1), pl.dslice(slot_idx, 1)] = jnp.where(
            ok, wv_ref[pl.dslice(i, 1)][None], old_val
        )
        return 0

    jax.lax.fori_loop(0, k, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def commit(tkeys, tvers, tvals, wkeys, wvals, active, *, interpret: bool = True):
    """Sequential commit of K writes. Returns (keys, vers, vals, overflow)."""
    nb, s, vw = tvals.shape
    kk = wkeys.shape[0]
    whole = lambda shape: pl.BlockSpec(shape, lambda: (0,) * len(shape))
    okeys, overs, ovals, ovf = pl.pallas_call(
        _commit_kernel,
        in_specs=[
            whole((kk, 2)),
            whole((kk, vw)),
            whole((kk,)),
            whole((nb, s, 2)),
            whole((nb, s)),
            whole((nb, s, vw)),
        ],
        out_specs=[
            whole((nb, s, 2)),
            whole((nb, s)),
            whole((nb, s, vw)),
            whole((1,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, s, 2), U32),
            jax.ShapeDtypeStruct((nb, s), U32),
            jax.ShapeDtypeStruct((nb, s, vw), U32),
            jax.ShapeDtypeStruct((1,), U32),
        ],
        input_output_aliases={3: 0, 4: 1, 5: 2},
        interpret=interpret,
    )(wkeys, wvals, active.astype(U32), tkeys, tvers, tvals)
    return okeys, overs, ovals, ovf[0].astype(bool)
