"""Quickstart: the FastFabric engine in 60 seconds.

Runs one round of money-transfer transactions through the full
execute-order-validate-commit flow under both configs, verifies the chain,
and shows the plug-and-play invariant (identical world state).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import engine
from repro.core import world_state as ws


def main() -> None:
    print("=== FastFabric on JAX: quickstart ===\n")
    digests = {}
    for name, cfg in (("fabric-1.2 (baseline)", engine.FABRIC_V12),
                      ("fastfabric (O-I..P-III)", engine.FASTFABRIC)):
        eng = engine.FabricEngine(cfg)
        props = eng.make_proposals(500, seed=42)
        eng.run_round(props)  # warmup (jit compile)
        stats = eng.run_round(eng.make_proposals(500, seed=43))
        checks = eng.verify()
        # The baseline keeps peer state in the sorted (LevelDB-like) store,
        # so compare the endorser replicas — hash tables in every config.
        digests[name] = np.asarray(ws.state_digest(eng.endorser_state))
        print(f"{name:26s} {stats.tps:10,.0f} tx/s  "
              f"valid {stats.n_valid}/{stats.n_txs}  checks={checks}")
        if eng.store:
            eng.store.close()

    a, b = digests.values()
    print(f"\nworld-state digests match across configs: "
          f"{bool(np.array_equal(a, b))}")
    print("(the optimizations change throughput, never semantics)")


if __name__ == "__main__":
    main()
