"""End-to-end driver: train a ~100M-parameter qwen2-style model for a few
hundred steps on the deterministic ID-ordered pipeline, with hash-chained
checkpoints and a simulated mid-run failure + exact restore.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The model is a scaled member of the qwen2 family (same block math as the
full config, reduced width/depth). Loss on the affine-recurrence task
drops steeply within a few hundred steps on CPU.
"""

import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.data import pipeline
from repro.models.lm import LM
from repro.training import optimizer, train_step as ts_lib

# ~100M params: 8 layers x d_model 512 (GQA 8h/2kv) x d_ff 2048, vocab 8192.
CFG_100M = ModelConfig(
    name="qwen2-100m", family="dense", n_layers=8, d_model=512,
    n_heads=8, n_kv=2, d_head=64, d_ff=2048, vocab=8192, qkv_bias=True,
    dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    print(f"model: {CFG_100M.name}  params={CFG_100M.n_params()/1e6:.1f}M")
    model = LM(CFG_100M, vocab_chunk=64)
    tcfg = ts_lib.TrainConfig(
        opt=optimizer.AdamWConfig(lr=1e-3, warmup_steps=20,
                                  total_steps=args.steps),
        microbatches=2,
    )
    dcfg = pipeline.DataConfig(vocab=256, seq_len=args.seq,
                               global_batch=args.batch)
    step_fn = jax.jit(ts_lib.make_train_step(model, tcfg),
                      donate_argnums=(0,))
    ckdir = tempfile.mkdtemp(prefix="ff_ckpt_")
    ckpt = Checkpointer(ckdir, keep=2)

    def batch_for(i):
        b = pipeline.global_batch_for_step(dcfg, i)
        return jax.tree.map(
            lambda x: None if x is None else jnp.asarray(x), b,
            is_leaf=lambda x: x is None)

    state = ts_lib.init_state(model, jax.random.PRNGKey(0))
    kill_at = args.steps // 2
    print(f"training; simulated failure at step {kill_at}")
    for i in range(kill_at):
        state, m = step_fn(state, batch_for(i))
        if i % 25 == 0:
            print(f"  step {i:4d} loss {float(m['loss']):.4f}")
        if (i + 1) % 50 == 0:
            ckpt.save(i + 1, state)
    ckpt.save(kill_at, state, blocking=True)
    loss_before_kill = float(m["loss"])
    del state
    print(f"  !! node failure at step {kill_at} "
          f"(loss was {loss_before_kill:.4f})")

    # --- restart path: restore + verify chain + resume the data stream ---
    like = ts_lib.init_state(model, jax.random.PRNGKey(0))
    state, start = ckpt.restore(like)
    assert ckpt.verify_chain()
    print(f"  restored checkpoint step {start}; chain verified; resuming")
    for i in range(start, args.steps):
        state, m = step_fn(state, batch_for(i))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"  step {i:4d} loss {float(m['loss']):.4f}")
    print(f"final loss: {float(m['loss']):.4f}")
    ckpt.close()
    shutil.rmtree(ckdir, ignore_errors=True)


if __name__ == "__main__":
    main()
