"""Serving scenario: continuous batching through the fabric engine.

Submits a burst of requests to the slot-based server (admission ordered on
the metadata plane, slots tracked in the versioned world state), then
verifies the outputs against independent single-request generation.

    PYTHONPATH=src python examples/fabric_serve.py
"""

import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import LM, Batch
from repro.serving.engine import Request, ServeEngine

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256,
    n_heads=4, n_kv=2, d_head=64, d_ff=1024, vocab=4096, dtype="float32",
)


def main() -> None:
    model = LM(CFG)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=4, max_len=96)

    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab, 24).astype(np.int32),
                    max_new=16)
            for i in range(10)]
    print(f"10 requests, 4 slots, max_new=16 "
          f"(continuous batching, slot reuse)")
    t0 = time.time()
    eng.run(reqs)
    wall = time.time() - t0
    print(f"served {eng.tokens_out} tokens in {wall:.1f}s "
          f"({eng.tokens_out / wall:,.0f} tok/s, {eng.steps} engine steps)")

    # Spot-check against independent generation.
    r = reqs[3]
    cache = model.init_cache(1, 64)
    logits, cache = model.prefill(
        params, Batch(tokens=jax.numpy.asarray(r.prompt)[None]), cache)
    want = [int(jax.numpy.argmax(logits[0]))]
    pos = len(r.prompt)
    for _ in range(15):
        logits, cache = model.decode_step(
            params, cache, jax.numpy.asarray([want[-1]], jax.numpy.int32),
            jax.numpy.int32(pos))
        want.append(int(jax.numpy.argmax(logits[0])))
        pos += 1
    print(f"req 3 matches independent greedy generation: {r.out == want}")
    print(f"request ledger versions (2 == assigned+retired exactly once): "
          f"{[eng.request_version(r.rid) for r in reqs[:5]]}")


if __name__ == "__main__":
    main()
